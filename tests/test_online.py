"""Online adaptation plane (DESIGN.md §11): rolling accuracy tracker,
closed-loop fleet training, drift recovery, artifact hot-swap, and the
viability fallback — simulator side and serving side.
"""
import numpy as np
import pytest

from repro.core.online import OnlineAdapter, OnlineFleet, RollingAccuracy
from repro.core.prediction_plane import PredictionPlane
from repro.core.simulator import SimConfig, run_sim
from repro.testing import make_store, make_trained_predictor

DRIFT_SCENARIOS = ("tier-drift", "app-drift", "colocation-drift",
                   "drift-fallback")


# ---------------------------------------------------------------------------
# RollingAccuracy
# ---------------------------------------------------------------------------
def test_rolling_accuracy_no_evidence_is_viable():
    tr = RollingAccuracy(window=4, n=3, min_count=2)
    np.testing.assert_array_equal(tr.accuracy(), [1.0, 1.0, 1.0])
    assert tr.viable(0.9).all()


def test_rolling_accuracy_masked_updates_and_window():
    tr = RollingAccuracy(window=2, n=2, min_count=1)
    tr.update(np.array([0.5, 0.9]), mask=np.array([True, False]))
    assert tr.accuracy()[0] == pytest.approx(0.5)
    assert tr.accuracy()[1] == 1.0            # element 1 never updated
    tr.update(np.array([0.1, 0.1]))
    tr.update(np.array([0.3, 0.3]))           # element 0 ring: [0.1, 0.3]
    assert tr.accuracy()[0] == pytest.approx(1.0 - 0.2)
    assert tr.accuracy()[1] == pytest.approx(1.0 - 0.2)


def test_rolling_accuracy_clips_errors_and_gates_viability():
    tr = RollingAccuracy(window=4, n=1, min_count=2)
    tr.update(np.array([7.0]))                # clipped to 1.0
    assert tr.viable(0.5)[0]                  # count < min_count
    tr.update(np.array([1.0]))
    assert tr.accuracy()[0] == pytest.approx(0.0)
    assert not tr.viable(0.5)[0]


# ---------------------------------------------------------------------------
# OnlineFleet (unit level)
# ---------------------------------------------------------------------------
def _tiny_fleet(T=3, warmup=0.0, retrain=0.0, **kw):
    node_of = np.tile(np.array([0, 1, 0, 1]), (T, 1))
    app_of = np.array([0, 0, 1, 1])
    return OnlineFleet(node_of, app_of, n_nodes=2, n_apps=2,
                       prior_rtt=[2.0, 4.0], warmup_s=warmup,
                       retrain_every_s=retrain, **kw)


def test_fleet_serves_prior_until_trained():
    fleet = _tiny_fleet()
    X = fleet.features(0, np.array([0, 1]), np.zeros((3, 4)), 0.0)
    assert X.shape == (3, 2, 4)               # (T, C, N + A)
    np.testing.assert_array_equal(fleet.predict(0, X), 2.0)
    np.testing.assert_array_equal(
        fleet.predict(1, fleet.features(1, np.array([2, 3]),
                                        np.zeros((3, 4)), 0.0)), 4.0)


def test_fleet_features_count_busy_per_app_on_node():
    fleet = _tiny_fleet()
    busy_until = np.array([[5.0, 0.0, 5.0, 0.0]] * 3)   # replicas 0,2 busy
    X = fleet.features(0, np.array([0, 1]), busy_until, now=1.0)
    # candidate 0 on node 0: one busy app-0 replica + one busy app-1
    np.testing.assert_array_equal(X[0, 0], [1, 0, 1, 1])
    # candidate 1 on node 1: nothing busy there
    np.testing.assert_array_equal(X[0, 1], [0, 1, 0, 0])


def test_fleet_learns_node_speed_and_versions_bump():
    rng = np.random.default_rng(0)
    fleet = _tiny_fleet(T=2, min_obs=4)
    cand = np.array([0, 1])
    idle = np.zeros((2, 4))
    X = fleet.features(0, cand, idle, 0.0)
    # node 0 serves in ~1s, node 1 in ~3s; alternate picks
    for i in range(30):
        picks = np.full(2, i % 2)
        Xp = X[np.arange(2), picks]
        rtt = np.where(picks == 0, 1.0, 3.0) + rng.normal(0, 0.01, 2)
        fleet.observe(0, Xp, rtt, finish=np.full(2, float(i)),
                      predicted=fleet.predict(0, X)[np.arange(2), picks])
    assert fleet.versions[0] == 0
    fleet.retrain(now=100.0)
    assert fleet.versions[0] == 1 and fleet.trained[:, 0].all()
    pred = fleet.predict(0, X)
    assert np.all(pred[:, 0] < pred[:, 1])            # node 0 is faster
    assert pred[:, 0] == pytest.approx(1.0, abs=0.1)
    assert pred[:, 1] == pytest.approx(3.0, abs=0.1)


def test_fleet_training_only_uses_completed_observations():
    fleet = _tiny_fleet(T=1, min_obs=2)
    cand = np.array([0, 1])
    X = fleet.features(0, cand, np.zeros((1, 4)), 0.0)
    for i in range(8):
        fleet.observe(0, X[:, 0], np.array([2.0]),
                      finish=np.array([1000.0]),    # never completes
                      predicted=np.array([2.0]))
    fleet.retrain(now=10.0)
    assert not fleet.trained.any()            # no completed data yet
    fleet.retrain(now=2000.0)
    assert fleet.trained[:, 0].all()


def test_fleet_accuracy_folds_only_after_completion():
    fleet = _tiny_fleet(T=2)
    fleet.observe(0, np.zeros((2, 4)), np.array([1.0, 1.0]),
                  finish=np.array([5.0, 50.0]),
                  predicted=np.array([1.5, 2.0]))
    fleet.fold_pending(now=10.0)              # trial 0 completed only
    assert fleet.trackers[0].count.tolist() == [1, 0]
    assert fleet.accuracy(0)[0] == pytest.approx(0.5)
    fleet.fold_pending(now=60.0)
    assert fleet.trackers[0].count.tolist() == [1, 1]
    assert fleet.accuracy(0)[1] == pytest.approx(0.0)   # err 1.0 clipped


def test_fleet_retrain_cadence():
    fleet = _tiny_fleet(T=1, warmup=10.0, retrain=5.0, min_obs=1)
    X = fleet.features(0, np.array([0, 1]), np.zeros((1, 4)), 0.0)
    fleet.observe(0, X[:, 0], np.array([1.0]), np.array([0.5]),
                  np.array([1.0]))
    assert not fleet.maybe_retrain(3.0)       # before warmup
    assert fleet.maybe_retrain(10.0)
    assert not fleet.maybe_retrain(12.0)      # within the cadence
    assert fleet.maybe_retrain(15.0)
    frozen = _tiny_fleet(T=1, warmup=10.0, retrain=0.0, min_obs=1)
    frozen.observe(0, X[:, 0], np.array([1.0]), np.array([0.5]),
                   np.array([1.0]))
    assert frozen.maybe_retrain(10.0)
    assert not frozen.maybe_retrain(1e9)      # frozen after first train


# ---------------------------------------------------------------------------
# closed-loop simulator: drift + recovery properties
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tier_drift_runs():
    """Small tier-drift grid: frozen vs retrained vs oracle."""
    base = dict(n_trials=12, n_requests=400, seed=5,
                apps=("motioncor2", "fft_mock", "gctf", "ctffind4"),
                arrival_rate=1.0, heterogeneity=0.05,
                interference_strength=0.2, node_tiers=(-0.6, 0.0, 1.8),
                t_drift=80.0, drift_tier_shuffle=True,
                closed_loop=True, online_warmup_s=40.0, online_window=120)
    frozen = run_sim(SimConfig(retrain_every_s=0.0, **base), "perf_aware")
    online = run_sim(SimConfig(retrain_every_s=12.0, **base), "perf_aware")
    oracle = run_sim(SimConfig(**base), "oracle")
    return base, frozen, online, oracle


def test_retrain_improves_accuracy_after_drift(tier_drift_runs):
    """The satellite property: after the drift the retrained fleet's
    rolling accuracy recovers while the frozen fleet's stays degraded."""
    _, frozen, online, _ = tier_drift_runs
    acc_f = frozen["online"]["accuracy"].mean()
    acc_o = online["online"]["accuracy"].mean()
    assert acc_o > acc_f + 0.2, (acc_f, acc_o)
    assert acc_o > 0.6
    assert len(online["online"]["retrain_times"]) > \
        len(frozen["online"]["retrain_times"]) == 1


def test_retrain_recovers_post_drift_rtt(tier_drift_runs):
    base, frozen, online, oracle = tier_drift_runs
    post = frozen["req_t"] >= base["t_drift"]
    f = frozen["rtts"][:, post].mean()
    o = online["rtts"][:, post].mean()
    orc = oracle["rtts"][:, post].mean()
    assert orc < o < f
    assert (f - o) / (f - orc) >= 0.4          # small-grid recovery floor


def test_oracle_ignores_closed_loop_knobs(tier_drift_runs):
    """The oracle reads state.actual only: retrain cadence must not
    change its results (no fleet is even built for it)."""
    base, _, _, oracle = tier_drift_runs
    again = run_sim(SimConfig(retrain_every_s=12.0, **base), "oracle")
    np.testing.assert_array_equal(oracle["rtts"], again["rtts"])
    assert "online" not in oracle


def test_fallback_threshold_changes_routing():
    base = dict(n_trials=10, n_requests=300, seed=2,
                apps=("motioncor2", "fft_mock", "gctf", "ctffind4"),
                arrival_rate=1.0, heterogeneity=0.05,
                interference_strength=0.2, node_tiers=(-0.6, 0.0, 1.8),
                t_drift=80.0, drift_tier_shuffle=True, closed_loop=True,
                online_warmup_s=40.0, online_window=120,
                retrain_every_s=0.0)
    plain = run_sim(SimConfig(**base), "perf_aware")
    guarded = run_sim(SimConfig(fallback_threshold=0.55, **base),
                      "perf_aware")
    # a frozen fleet drops below the viability floor post-drift, so the
    # guarded run must route differently (least_conn fallback)
    assert not np.array_equal(plain["chosen"], guarded["chosen"])
    assert plain["online"]["accuracy"].mean() < 0.55


def test_drift_changes_regime_only_after_t_drift():
    base = dict(n_trials=6, n_requests=200, seed=4, arrival_rate=2.0,
                t_drift=30.0, drift_tier_shuffle=True,
                node_tiers=(-0.5, 0.0, 1.0))
    drift = run_sim(SimConfig(**base), "least_conn")
    still = run_sim(SimConfig(**{**base, "t_drift": None,
                                 "drift_tier_shuffle": False}),
                    "least_conn")
    pre = drift["req_t"] < 30.0
    np.testing.assert_array_equal(drift["rtts"][:, pre],
                                  still["rtts"][:, pre])
    assert not np.array_equal(drift["rtts"][:, ~pre],
                              still["rtts"][:, ~pre])


# ---------------------------------------------------------------------------
# artifact hot-swap (OnlineAdapter -> PredictionPlane)
# ---------------------------------------------------------------------------
def test_hot_swap_version_monotonic_and_served():
    """Retraining bumps artifact_version monotonically and the plane
    serves the NEW artifact after re-registration (bucket restack)."""
    store = make_store(seed=30)
    pred = make_trained_predictor("hotswap", store, "lr", seed=31,
                                  n_samples=48)
    plane = PredictionPlane()
    assert plane.register_predictor(pred)
    v0 = pred.artifact_version
    before = plane.predict_all()[("hotswap", "node-0")].rtt_pred

    # retrain on shifted targets: version must move, prediction must move
    rng = np.random.default_rng(7)
    w_pts = int(round(5.0 / 0.2))
    versions = [v0]
    for r in range(2):
        for _ in range(40):
            pred.observe_task(10.0 + rng.uniform(0, 2),
                              {w: rng.standard_normal((10, w_pts))
                               for w in (5.0,)})
        X = rng.standard_normal((48, 4, w_pts)).astype(np.float32)
        y = rng.uniform(8.0, 12.0, 48).astype(np.float32)
        from repro.core.features import extract_features
        feats = np.asarray(extract_features(X)).reshape(48, -1)
        pred.scaler_X.fit(feats)
        pred.y_lo, pred.y_hi = float(y.min()), float(y.max())
        pred.choice.model.fit(pred.scaler_X.transform(feats),
                              (y - pred.y_lo) / (pred.y_hi - pred.y_lo))
        pred.artifact_version += 1
        versions.append(pred.artifact_version)
        assert plane.register_predictor(pred)     # hot swap
    after = plane.predict_all()[("hotswap", "node-0")].rtt_pred
    assert versions == sorted(set(versions))      # strictly increasing
    assert after != pytest.approx(before, rel=1e-3)
    assert 5.0 < after < 16.0                     # serves the new scale


def test_online_adapter_retrains_and_swaps_on_cadence():
    store = make_store(seed=40)
    pred = make_trained_predictor("adapt", store, "lr", seed=41,
                                  n_samples=48)
    pred.correlations_valid = True     # keep the injected (w, k) choice
    plane = PredictionPlane()
    plane.register_predictor(pred)
    adapter = OnlineAdapter(plane, retrain_every_s=30.0)
    adapter.track(pred)
    v0 = pred.artifact_version
    rng = np.random.default_rng(8)
    w_pts = int(round(5.0 / 0.2))

    def feed(n):
        # tight RTT spread so the CONFIRM bootstrap check passes
        for _ in range(n):
            adapter.observe("adapt", "node-0", float(rng.uniform(2.0, 2.2)),
                            {w: rng.standard_normal((10, w_pts))
                             for w in (5.0,)},
                            predicted=2.1)

    feed(60)
    t0 = store.clock.now()
    assert adapter.maybe_retrain(t0) == []        # first call arms cadence
    assert adapter.maybe_retrain(t0 + 10.0) == []  # not due yet
    swapped = adapter.maybe_retrain(t0 + 31.0)
    assert swapped == [("adapt", "node-0")]
    assert pred.artifact_version > v0
    assert adapter.swaps[-1][2] == pred.artifact_version
    assert 0.0 < adapter.accuracy("adapt", "node-0") <= 1.0


def test_manager_builds_adapter_over_active_predictors():
    from repro.core.manager import PredictionManager
    store = make_store(seed=60)
    mgr = PredictionManager()
    for i in range(3):
        p = make_trained_predictor(f"m{i}", store, "lr", seed=60 + i)
        mgr.predictors[(f"m{i}", "node-0")] = p
        mgr.paused[(f"m{i}", "node-0")] = False
    mgr.pause("m2", "node-0")
    adapter = mgr.online_adapter(retrain_every_s=42.0)
    assert set(adapter.predictors) == {("m0", "node-0"), ("m1", "node-0")}
    assert adapter.plane is mgr.plane
    assert adapter.retrain_every_s == 42.0


def test_adapter_viability_rule():
    adapter = OnlineAdapter(PredictionPlane(), min_count=2)
    store = make_store(seed=50)
    pred = make_trained_predictor("via", store, "lr", seed=51)
    adapter.track(pred)
    assert adapter.viable("via", "node-0", 0.9)      # no evidence
    for _ in range(4):
        adapter.trackers[("via", "node-0")].update(np.array([0.9]))
    assert not adapter.viable("via", "node-0", 0.5)
    assert adapter.viable("unknown", "nowhere", 0.99)  # untracked


# ---------------------------------------------------------------------------
# bench_online smoke goldens
# ---------------------------------------------------------------------------
def test_bench_online_smoke_recovery_pinned():
    """Golden pins for the bench_online --smoke grid (deterministic):
    recovery fraction per drift scenario, within a loose band so libm /
    BLAS platform noise cannot flip it but logic changes will."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.bench_online import drift_recovery

    got = drift_recovery("tier-drift", tuple(range(4)), n_trials=4)
    # bands are wider than the sim's elementwise goldens because the
    # closed loop routes through LAPACK solves: cross-BLAS bit drift
    # compounds chaotically over 500+ routing decisions
    assert got["recovery"] == pytest.approx(0.608, abs=0.08)
    assert got["accuracy_online"] == pytest.approx(0.80, abs=0.06)
    assert got["accuracy_frozen"] == pytest.approx(0.33, abs=0.06)
    assert got["frozen"]["post_rtt"] == pytest.approx(6.98, rel=0.05)
    assert got["online"]["post_rtt"] == pytest.approx(5.34, rel=0.05)
    assert got["oracle"]["post_rtt"] == pytest.approx(4.28, rel=0.05)


@pytest.mark.slow
def test_bench_online_full_grid_gate():
    """The acceptance criterion on the full drift grid: >= 50% recovery
    on every registered drift scenario (12 seeds, registered sizes)."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.bench_online import RECOVERY_FLOOR, drift_recovery

    for name in DRIFT_SCENARIOS:
        r = drift_recovery(name, tuple(range(12)))
        assert r["recovery"] >= RECOVERY_FLOOR, (name, r["recovery"])
        assert r["accuracy_online"] > r["accuracy_frozen"], name
        if "fallback" in r:
            assert r["fallback"]["gain"] > 0, name
