"""Batched campaign runner (DESIGN.md §10): batched-vs-serial parity for
every registered scenario, determinism, and the stacking preconditions.
"""
import numpy as np
import pytest

from repro.core.balancer import ClusterState, make_policy
from repro.core.campaign import (DEFAULT_POLICIES, SUMMARY_STATS,
                                 campaign_table, run_campaign,
                                 run_campaign_serial, run_scenario,
                                 stack_clusters)
from repro.core.scenarios import get_scenario, scenario_names
from repro.core.simulator import SimConfig, _build_cluster

SMALL = dict(seeds=(0, 1, 2, 3), n_trials=4, n_requests=50)
STATS = SUMMARY_STATS + ("hedged",)


@pytest.mark.parametrize("name", scenario_names())
def test_batched_matches_serial_per_scenario(name):
    """The acceptance gate: batched-campaign vs serial-run_sim parity
    within 1e-5 for every registered scenario, every policy, every
    seed (in practice the paths are bit-identical)."""
    batched = run_scenario(name, **SMALL)
    serial = run_campaign_serial([name], **SMALL)[name]
    for pol in batched:
        for k in STATS:
            np.testing.assert_allclose(
                batched[pol].per_seed[k], serial[pol].per_seed[k],
                rtol=1e-5, atol=1e-7, err_msg=f"{name}/{pol}/{k}")
        assert batched[pol].n_hedged == serial[pol].n_hedged
    for pol in DEFAULT_POLICIES:
        np.testing.assert_allclose(
            batched[pol].inefficiency_pct, serial[pol].inefficiency_pct,
            rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("name", ("tier-drift", "app-drift",
                                  "colocation-drift", "drift-fallback"))
def test_drift_crossing_batched_matches_serial(name):
    """The registry-wide parity test above runs the drift scenarios too,
    but its shrunken horizon ends before t_drift.  This one compresses
    the timeline so warmup, several retrains, AND the drift transition
    all happen inside the run — the online fleet's batched per-trial
    ridge retraining must still match per-seed serial runs exactly."""
    kw = dict(seeds=(0, 1, 2), n_trials=3, n_requests=80,
              arrival_rate=2.0, online_warmup_s=8.0, retrain_every_s=6.0,
              t_drift=20.0)
    batched = run_scenario(name, **kw)
    serial = run_campaign_serial([name], **kw)[name]
    for pol in batched:
        for k in STATS:
            np.testing.assert_allclose(
                batched[pol].per_seed[k], serial[pol].per_seed[k],
                rtol=1e-5, atol=1e-7, err_msg=f"{name}/{pol}/{k}")


def test_hedged_policy_parity():
    """Hedging is stateful across the busy matrix — make sure the
    stacked pass still matches per-seed serial runs."""
    spec = get_scenario("baseline")
    name = "perf_aware"
    b = run_scenario(spec, policies=(name,), hedge_factor=0.7,
                     arrival_rate=4.0, **SMALL)
    s = run_campaign_serial([spec], policies=(name,), hedge_factor=0.7,
                            arrival_rate=4.0, **SMALL)[spec.name]
    assert b[name].n_hedged == s[name].n_hedged
    assert b[name].n_hedged > 0
    np.testing.assert_array_equal(b[name].per_seed["hedged"],
                                  s[name].per_seed["hedged"])
    assert b[name].per_seed["hedged"].sum() == b[name].n_hedged
    np.testing.assert_allclose(b[name].per_seed["mean_rtt"],
                               s[name].per_seed["mean_rtt"], rtol=1e-5)


def test_campaign_is_deterministic():
    r1 = run_campaign(["baseline", "churn"], **SMALL)
    r2 = run_campaign(["baseline", "churn"], **SMALL)
    for scen in r1:
        for pol in r1[scen]:
            for k in STATS:
                np.testing.assert_array_equal(
                    r1[scen][pol].per_seed[k], r2[scen][pol].per_seed[k])


def test_stacking_requires_a_shared_stream():
    cfgs = [SimConfig(seed=s, n_trials=2, n_requests=20) for s in (0, 1)]
    with pytest.raises(ValueError, match="arrival stream"):
        stack_clusters([_build_cluster(c) for c in cfgs])


def test_stacking_rejects_heterogeneous_knobs():
    spec = get_scenario("baseline")
    a = spec.compile(seed=0, n_trials=2, n_requests=20)
    b = spec.compile(seed=1, n_trials=2, n_requests=20, accuracy=0.3)
    with pytest.raises(ValueError, match="except seed"):
        stack_clusters([_build_cluster(a), _build_cluster(b)])


def test_random_seed_blocks_guard():
    pol = make_policy("random", seed_blocks=[(0, 2), (1, 2)])
    state = ClusterState(now=0.0, busy_until=np.zeros((3, 2)))
    with pytest.raises(ValueError, match="seed_blocks"):
        pol.score(state)


def test_policy_result_surface():
    cell = run_scenario("baseline", seeds=(0, 1), n_trials=3,
                        n_requests=30)
    r = cell["perf_aware"]
    assert r.scenario == "baseline" and r.seeds == (0, 1)
    assert r.per_seed["p99_rtt"].shape == (2,)
    assert r.inefficiency_pct is not None
    assert cell["oracle"].inefficiency_pct is None
    table = campaign_table({"baseline": cell})
    assert "perf_aware" in table and "oracle" not in table
    md = campaign_table({"baseline": cell}, markdown=True)
    assert md.startswith("| scenario |")


def test_include_oracle_false_skips_inefficiency():
    cell = run_scenario("baseline", include_oracle=False, seeds=(0, 1),
                        n_trials=2, n_requests=20)
    assert "oracle" not in cell
    assert cell["perf_aware"].inefficiency_pct is None
