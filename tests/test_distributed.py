"""Multi-device behaviour (subprocess with forced host devices):
sharded train step, elastic checkpoint reshard, compressed cross-pod psum,
and a reduced multi-pod dry-run lowering."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow   # multi-device subprocess compiles (CI full-suite job)

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_sub(code: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=ROOT)
    assert p.returncode == 0, p.stderr[-4000:]
    return p.stdout


def test_sharded_train_step_runs():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_config, TrainConfig
        from repro.launch.mesh import make_mesh
        from repro.parallel.sharding import axis_rules, make_rules
        from repro.training.train_step import make_train_state, make_train_step
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = get_config("deepseek-67b", smoke=True).resolve(tp=4, dp=2)
        tcfg = TrainConfig(microbatches=2)
        rules = make_rules(mesh, mode="train", fsdp=True, dp_axes=("data",))
        with axis_rules(rules):
            state = make_train_state(jax.random.PRNGKey(0), cfg, tcfg)
            step = make_train_step(cfg, tcfg, rules)
            batch = {"tokens": jnp.ones((8, 32), jnp.int32),
                     "labels": jnp.ones((8, 32), jnp.int32)}
            batch = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
            jstep = jax.jit(step)
            l0 = None
            for i in range(4):
                state, metrics = jstep(state, batch)
                if l0 is None: l0 = float(metrics["total_loss"])
            l1 = float(metrics["total_loss"])
        assert np.isfinite(l1)
        assert l1 < l0, (l0, l1)
        print("OK", l0, l1)
        """)
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import Checkpointer
        from repro.launch.mesh import make_mesh
        d = tempfile.mkdtemp()
        mesh1 = make_mesh((4, 2), ("data", "model"))
        w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        w1 = jax.device_put(w, NamedSharding(mesh1, P("data", "model")))
        ck = Checkpointer(d, use_async=False)
        ck.save(5, {"w": w1}, blocking=True)
        # restore onto a DIFFERENT mesh shape + sharding (elastic resize)
        mesh2 = make_mesh((2, 4), ("data", "model"))
        template = {"w": jnp.zeros((8, 8), jnp.float32)}
        shardings = {"w": NamedSharding(mesh2, P("model", None))}
        r = ck.restore(template, shardings=shardings)
        np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(w))
        assert r["w"].sharding.spec == P("model", None)
        print("OK")
        """)
    assert "OK" in out


def test_compressed_crosspod_allreduce():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.optim.compression import make_compressed_allreduce
        mesh = make_mesh((4, 2), ("pod", "data"))
        fn = make_compressed_allreduce(mesh, axis_name="pod")
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.standard_normal((16, 32)).astype(np.float32))}
        r = {"w": jnp.zeros((16, 32), jnp.float32)}
        mean, res = jax.jit(fn)(g, r)
        # pod-replicated input -> mean == input, small quantization error
        err = float(jnp.max(jnp.abs(mean["w"] - g["w"])))
        scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
        assert err <= scale + 1e-6, (err, scale)
        # error feedback captured the residual
        assert float(jnp.max(jnp.abs(res["w"]))) <= scale + 1e-6
        print("OK", err)
        """)
    assert "OK" in out


def test_reduced_dryrun_multipod_lowering():
    out = run_sub("""
        import jax
        from repro.launch.mesh import make_mesh
        from repro.launch.specs import build_cell, lower_cell
        from repro.launch.hlo import collective_bytes, cost_dict
        mesh = make_mesh((2, 2, 4), ("pod", "data", "model"))
        cell = build_cell("deepseek-67b", "train_4k", mesh,
                          overrides={"num_layers": 2, "d_model": 256,
                                     "num_heads": 8, "num_kv_heads": 4,
                                     "head_dim": 32, "d_ff": 512,
                                     "vocab_size": 1024})
        compiled = lower_cell(cell).compile()
        mem = compiled.memory_analysis()
        cost = cost_dict(compiled)
        assert cost["flops"] > 0
        colls = collective_bytes(compiled.as_text())
        assert colls["_total"] > 0, colls
        print("OK", colls["_counts"])
        """, devices=16)
    assert "OK" in out
