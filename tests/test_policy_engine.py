"""Unified policy engine: cross-layer parity + new simulator scenarios.

The tentpole guarantee: given identical cluster state, the simulator
path (vectorized ``Policy.pick``), the scalar path (``Policy.choose``),
and the live-router path (``MorpheusRouter.route``) pick the SAME
replica for every registered policy — there is exactly one
implementation of each policy.
"""
import inspect
from dataclasses import replace

import numpy as np
import pytest

from repro.core.balancer import (ClusterState, LeastConnections, PerfAware,
                                 POLICIES, Policy, Replica, make_policy)
from repro.core.simulator import SimConfig, run_sim, scheduling_inefficiency

FAST = SimConfig(n_trials=20, n_requests=120, arrival_rate=3.0)


def _random_cluster(rng, C=6, now=10.0):
    busy = now + rng.uniform(-5.0, 5.0, C)
    queue = rng.integers(0, 4, C).astype(float)
    pred = rng.uniform(1.0, 10.0, C)
    actual = rng.uniform(1.0, 10.0, C)
    replicas = [Replica(idx=i, app="a", node=f"n{i}", busy_until=busy[i],
                        queue_depth=queue[i]) for i in range(C)]
    state = ClusterState(now=now, busy_until=busy[None, :].copy(),
                         queue_depth=queue[None, :].copy(),
                         predicted=pred[None, :].copy(),
                         actual=actual[None, :].copy())
    return replicas, state, pred, actual


# ---------------------------------------------------------------------------
# parity: vectorized (simulator) path == scalar (router) path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(POLICIES))
def test_vector_and_scalar_paths_agree(name):
    rng = np.random.default_rng(42)
    vec = make_policy(name, seed=7)
    scal = make_policy(name, seed=7)      # shared seed for `random`
    for _ in range(25):
        replicas, state, pred, actual = _random_cluster(rng)
        a = int(vec.pick(state)[0])
        b = scal.choose(replicas, now=state.now, predicted=pred,
                        actual=actual)
        assert a == b, (name, a, b)


@pytest.mark.parametrize("name", sorted(POLICIES))
def test_vectorized_trials_match_independent_scalar_runs(name):
    """T parallel trials must behave like T independent 1-trial clusters
    (the property run_sim relies on)."""
    if name == "random":
        pytest.skip("draw order differs between (T,C) and (1,C) shapes")
    rng = np.random.default_rng(3)
    T, C, now = 5, 4, 10.0
    busy = now + rng.uniform(-5.0, 5.0, (T, C))
    pred = rng.uniform(1.0, 10.0, (T, C))
    actual = rng.uniform(1.0, 10.0, (T, C))
    state = ClusterState(now=now, busy_until=busy.copy(),
                         predicted=pred.copy(), actual=actual.copy())
    picks = make_policy(name, seed=0).pick(state)
    for t in range(T):
        one = ClusterState(now=now, busy_until=busy[t:t + 1].copy(),
                           predicted=pred[t:t + 1].copy(),
                           actual=actual[t:t + 1].copy())
        assert int(make_policy(name, seed=0).pick(one)[0]) == picks[t]


def test_router_dispatches_through_engine():
    """The live router must produce exactly the engine's picks."""
    from repro.serving.router import MorpheusRouter

    class _StubReplica:
        def __init__(self, node, pending, max_batch=2):
            self.node = node
            self.max_batch = max_batch
            self._pending = pending

        def pending(self):
            return self._pending

        def submit(self, req):
            self._pending += 1

    for name in sorted(POLICIES):
        reps = [_StubReplica(f"n{i}", pending=i % 3) for i in range(4)]
        router = MorpheusRouter(reps, policy=name, seed=11)
        for i in range(4):
            router.kb.put("serve", f"n{i}", 0.0, 1.0 + 2.0 * i)
        if name == "oracle":
            # true RTTs exist only in simulation; the router must refuse
            # rather than silently serve predictions as an "oracle"
            with pytest.raises(ValueError):
                router.route(object())
            continue
        mirror = make_policy(name, seed=11)
        for step in range(8):
            want = int(mirror.pick(router.cluster_state())[0])
            got = router.route(object())
            assert got == want, (name, step, got, want)


def test_no_policy_name_dispatch_chains():
    """simulator and router must not re-implement policies inline."""
    import repro.core.simulator as sim
    import repro.serving.router as rt
    for mod in (sim, rt):
        src = inspect.getsource(mod)
        assert "elif policy" not in src and "elif self.policy_name" not in src
        assert 'policy == "' not in src and 'policy_name == "' not in src


def test_make_policy_unknown_name():
    with pytest.raises(KeyError):
        make_policy("weighted_magic")
    with pytest.raises(KeyError):
        run_sim(SimConfig(n_trials=2, n_requests=5), "weighted_magic")


# ---------------------------------------------------------------------------
# hedging semantics (satellite: previously inverted vs the docstring)
# ---------------------------------------------------------------------------
def test_hedges_when_chosen_prediction_exceeds_factor():
    # chosen: idle r0 (pred 5, score 5); busy r1 completes in 2 + 4 = 6;
    # 5 > 0.7 x 6 -> the prediction is risky relative to waiting -> hedge
    pol = PerfAware(hedge_factor=0.7)
    reps = [Replica(0, "a", "n0", busy_until=0.0),
            Replica(1, "a", "n1", busy_until=2.0)]
    assert pol.hedge_candidates(reps, 0.0, [5.0, 4.0]) == [0, 1]


def test_no_hedge_when_predictions_close():
    # the pre-fix implementation hedged whenever best ~ second; the
    # documented rule does not (2.0 < 1.5 x 3.0)
    pol = PerfAware(hedge_factor=1.5)
    reps = [Replica(0, "a", "n0", busy_until=0.0),
            Replica(1, "a", "n1", busy_until=0.0),
            Replica(2, "a", "n2", busy_until=2.0)]
    assert pol.hedge_candidates(reps, 0.0, [2.0, 2.1, 1.0]) == [0]


def test_no_hedge_without_busy_reference():
    pol = PerfAware(hedge_factor=0.5)
    reps = [Replica(0, "a", "n0"), Replica(1, "a", "n1")]
    assert pol.hedge_candidates(reps, 0.0, [10.0, 12.0]) == [0]


def test_hedge_candidates_wraps_hedge_plan():
    """One hedge decision: the scalar API must replay the vector path."""
    pol = PerfAware(hedge_factor=0.7)
    rng = np.random.default_rng(5)
    for _ in range(30):
        replicas, state, pred, _ = _random_cluster(rng)
        scores = pol.score(state)
        picks = np.argmin(scores, axis=1)
        second, mask = pol.hedge_plan(state, picks, scores)
        want = [int(picks[0]), int(second[0])] if mask[0] else [int(picks[0])]
        assert pol.hedge_candidates(replicas, state.now, pred) == want


def test_hedge_plan_fires_on_forced_slow_pick():
    pol = PerfAware(hedge_factor=1.5)
    state = ClusterState(now=0.0, busy_until=np.array([[0.0, 0.0, 2.0]]),
                         predicted=np.array([[10.0, 12.0, 1.0]]))
    picks = np.argmin(pol.score(state), axis=1)
    second, mask = pol.hedge_plan(state, picks)
    # score picks the busy-but-fast replica (wait 2 + pred 1 = 3); its
    # own prediction (1.0) never exceeds 1.5 x 3.0 -> no hedge
    assert int(picks[0]) == 2 and not bool(mask[0])
    # force the pick onto the slow idle replica -> hedge fires
    second, mask = pol.hedge_plan(state, np.array([0]))
    assert bool(mask[0]) and int(second[0]) != 0


def test_oracle_refuses_to_run_on_predictions():
    # no silent fallback: an oracle scored on noisy predictions would be
    # a mislabeled perf_aware run
    state = ClusterState(now=0.0, busy_until=np.zeros((1, 2)),
                         predicted=np.ones((1, 2)))
    with pytest.raises(ValueError):
        make_policy("oracle").pick(state)


# ---------------------------------------------------------------------------
# new simulator scenarios
# ---------------------------------------------------------------------------
def test_tail_metrics_reported_and_ordered():
    res = run_sim(FAST, "perf_aware")
    for k in ("mean_rtt", "p50_rtt", "p95_rtt", "p99_rtt"):
        assert res[k].shape == (FAST.n_trials,)
    assert (res["p50_rtt"] <= res["p95_rtt"] + 1e-9).all()
    assert (res["p95_rtt"] <= res["p99_rtt"] + 1e-9).all()
    assert set(res["per_app"]) == set(FAST.apps)


def test_least_conn_simulated():
    r = scheduling_inefficiency(FAST, "least_conn")
    assert np.isfinite(r["inefficiency_pct"])
    # queue-aware: no worse than blind random (generous noise margin)
    rd = scheduling_inefficiency(FAST, "random")
    assert r["inefficiency_pct"] <= rd["inefficiency_pct"] + 2.0


def test_hedged_perf_aware_fires_and_costs_resources():
    cfg = replace(FAST, arrival_rate=4.0, hedge_factor=0.7)
    base = replace(FAST, arrival_rate=4.0)
    hedged = run_sim(cfg, "perf_aware")
    plain = run_sim(base, "perf_aware")
    assert hedged["n_hedged"] > 0
    assert plain["n_hedged"] == 0
    # duplicates consume extra cpu-seconds
    assert hedged["cpu_s"].mean() > plain["cpu_s"].mean()


def test_stale_predictions_degrade_perf_aware():
    vals = []
    for lag in (0.0, 50.0):
        cfg = replace(FAST, prediction_lag_s=lag)
        vals.append(scheduling_inefficiency(cfg, "perf_aware")
                    ["inefficiency_pct"])
    assert vals[1] > vals[0], vals


def test_node_churn_raises_rtt():
    churned = replace(FAST, churn=(5.0, 60.0))
    a = run_sim(churned, "perf_aware")["mean_rtt"].mean()
    b = run_sim(FAST, "perf_aware")["mean_rtt"].mean()
    assert a > b, (a, b)


def test_least_conn_router_semantics():
    # with zero busy estimates the engine's least_conn reduces to classic
    # fewest-pending
    pol = LeastConnections()
    state = ClusterState(now=0.0, busy_until=np.zeros((1, 3)),
                         queue_depth=np.array([[4.0, 1.0, 2.0]]))
    assert int(pol.pick(state)[0]) == 1
