"""Render EXPERIMENTS.md from the dry-run + campaign artifacts and the
perf log.  Sections whose artifact is missing render a regeneration
note instead of failing.

Run after the dry-run / campaign bench:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
    PYTHONPATH=src python benchmarks/bench_campaign.py
    PYTHONPATH=src python experiments/generate_experiments.py
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import roofline  # noqa: E402

HEADER = """\
# EXPERIMENTS — Morpheus on a TPU v5e multi-pod fleet

Companion to DESIGN.md.  All artifacts regenerate with:

```bash
PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
PYTHONPATH=src python experiments/generate_experiments.py
PYTHONPATH=src python -m benchmarks.run
```

## §Reproduction — paper-claim validation (the faithful baseline)

The Morpheus pipeline (perfCorrelate -> FD balancing -> Table-2 zoo ->
Eq. 4-6 selection -> θ-retraining -> performance-aware LB) is validated
against the paper's own claims (benchmarks print the full tables):

| Paper claim | Our reproduction |
|---|---|
| Predictors reach up to ~95% accuracy; Table 4 RMSE mostly < 20% | normalized RMSE 1.8–12% per trained (app, node) predictor (`examples/quickstart.py`, fig6/table4 bench); some (app, node) pairs get **no** predictor within the τ budgets — exactly the paper's "–" cells |
| Prediction delay ≤ 10% of RTT, dominated by state retrieval (89.2% Fig. 9) | delay budget enforced by Eq. 4 selection; measured breakdown on the modeled Prometheus path: state ≈ 97–99%, inference < 1% (fig9 bench) |
| Fig. 10: state delay grows with (w, k); 60 s/100 metrics ≈ 35% RTT | retrieval model calibrated to the same shape: 25% at (60 s, 100) vs <15% at (5 s, 100) (fig10 bench) |
| Fig. 8: balancing removes 85–99% of samples | 85–97% removal under skewed arrivals (fig8 bench) |
| Fig. 11-1: inefficiency ≈ 0 at accuracy ≥ 0.8 | 8.0% @ p=0 -> 0.9% @ p=0.8 -> 0.0% @ p=1.0 (fig11 bench) |
| Fig. 11-2/3: baselines degrade with replica count; perf-aware flat | rr/random reach 21%/44% inefficiency/waste at 8 replicas vs 2.9%/6.8% perf-aware |
| Fig. 11-4: heterogeneity hurts static policies | rr 27% vs perf-aware ~0% at h=1.0 |
| Table 5: co-located predictors can raise RTT CoV | CoV rises on 3/5 apps when predictor bursts share the node (table5 bench) |
| No single correlation method wins (Fig. 4) | distance/MIC dominate for non-linear apps, Spearman for monotonic (fig4 bench) |

Beyond-paper (§Perf below, quantified in fig9): O(1) rolling features +
zero-copy ring-buffer state cut prediction latency by >100x vs the modeled
Prometheus path, directly answering the paper's "faster monitoring systems
are needed" conclusion; prediction-guided hedging reuses the predictors for
straggler mitigation.

## §Dry-run — 40 cells x 2 meshes

- Mesh: `(data=16, model=16)` single pod (256 chips) and `(pod=2, data=16,
  model=16)` multi-pod (512 chips); every runnable (arch x shape) cell
  lowers AND compiles on both (`experiments/artifacts/dryrun.json` holds
  memory_analysis, cost_analysis, and the parsed collective schedule).
- 8 cells are documented skips: `long_500k` for the 8 pure full-attention
  archs (DESIGN.md §4); SSM/hybrid run it.
- Compute path in the dry-run is the XLA reference (blockwise flash
  attention / chunked SSD); Pallas kernels are TPU-target, validated in
  interpret mode (`tests/test_kernels.py`).
- LIVE = arguments + outputs + temps − donation aliases, per device
  (v5e budget: 16 GB).

### Accounting notes (methodology, read before the tables)

1. XLA `cost_analysis()` counts a `while`-loop body ONCE (measured 1.04x
   for a 10-iteration scan).  Layer terms therefore come from UNROLLED
   depth-1/2 compiles (microbatch loop also unrolled):
   `total(L) = f(1) + (L-1)(f(2)-f(1))`.
2. The CPU backend promotes bf16 dot operands to f32, so some reported
   collective/memory bytes are ~2x what the TPU (native-bf16 MXU) moves;
   flagged where material.
3. Attention/SSD inner block loops remain scans (counted once) — compute
   terms under-count intra-attention FLOPs by up to ~10% at 4k seq.
4. In-place scatter (cache update) is charged by XLA's cost model as full
   operand traffic; real HBM traffic is one row per sequence.
"""

PERF_LOG = """\
## §Perf — hypothesis -> change -> measure log

The paper-faithful Morpheus baseline and all 40 baseline cells above were
measured FIRST; the three most interesting cells were then hillclimbed.
Cells: (A) `qwen3-moe-235b-a22b|train_4k` (worst useful-ratio among train
cells, memory-bound, over HBM budget), (B) `mistral-large-123b|prefill_32k`
(most collective-bound), (C) `qwen1.5-32b|decode_32k` (serving cell — most
representative of the paper's load-balancing setting; worst memory).

### Pre-baseline structural fixes (needed to get credible baselines)

| # | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| 0.1 | packed Mamba2 in_proj slices cut across 16-way shards, forcing full-activation gathers | split z/x/B/C/dt projections + per-part depthwise convs (TP-Mamba layout) | 1.5 GB collective-permute per layer -> 1.7 MB | confirmed |
| 0.2 | 48-layer residual stack (saved by remat) + hoisted f32 convert blow HBM | Megatron-SP: seq-shard the residual carries over "model" | mamba2 train temp 40.6 -> 5.8 GB | confirmed |
| 0.3 | saved (q,kv) score blocks across ALL attention tiles kept in bwd | jax.checkpoint on q-block and kv-step bodies (flash-style backward) | deepseek train temp 40.4 -> 10.7 GB (with 0.4) | confirmed |
| 0.4 | residual stack scales 1/microbatches | grad accumulation, 4 microbatches | (part of 0.3 row) | confirmed |
| 0.5 | GShard dispatch is quadratic in tokens-per-group | groups sized so S_g <= 2048 | moe-235b dispatch 1.3 PB (infeasible) -> 86 GB global | confirmed |
| 0.6 | repeat_kv on a seq-sharded cache makes GSPMD gather the seq dim | GQA-native grouped decode einsum + REPLICATED q (one token) | mistral decode temp 17.3 -> 8.7 GB; zamba 500k cache gathers (2x10.7 GB f32) eliminated | confirmed |
| 0.7 | scan xs/ys double-buffer the KV cache + hoist a full-stack f32 convert | caches as scan CARRIES updated via dynamic_update_index + donation + pinned out_shardings | mistral decode LIVE 24.9 -> 15.9 GB | confirmed |
| 0.8 | reshaping the sharded seq dim into (nb, blk) fragments its sharding | flash-decode single-shot over the seq-sharded cache | qwen1.5 decode: 1.6 GB all-gather per layer -> ~1 MB psum | confirmed |
| 0.9 | prefill ys materialise full-seq caches per device | seq-shard cache copies inside the layer body | mistral prefill temp 14.1 -> 12.9 GB | confirmed |
| 0.10 | 14 B/param optimizer state cannot fit 235B on 256 chips | bf16 master + bf16 moments when HBM-bound | arg 13.2 -> 7.6 GB/dev; convergence property-tested | confirmed |

### Cell B: mistral-large-123b prefill_32k (collective-bound)

| # | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| B.1 | HLO attribution shows the f32 residual gathered 3x/layer (qkv dot, mlp dot, constraint) + row-parallel ARs | Megatron-SP choreography: ONE gather at each norm output; attn/mlp outputs constrained seq-sharded pre-residual-add | collective 28.9 s -> 23.3 s (-19%) | confirmed |
| B.2 | constraining the row-parallel dot output seq-sharded makes GSPMD emit reduce-scatter instead of AR+slice | moved output constraints onto the dots; optimization_barrier to pin bf16 gathers | 23.3 s -> 23.3 s (no RS emitted; CPU GSPMD keeps f32 AR) | REFUTED — the remaining 2x is CPU f32-dot promotion; TPU-native bf16 collectives halve it (≈11 s analytic) |

### Cell C: qwen1.5-32b decode_32k (memory-bound serving cell)

| # | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| C.1 | one-hot cache update reads+rewrites the whole 26 GB cache per layer | scatter (.at[b, len].set) update | memory term 1.44 -> 0.98 s (-32%); LIVE 87.9 -> 63.1 GB; deepseek decode LIVE 15.2 -> 9.0 GB | confirmed |
| C.2 | int8 KV halves cache residency + read traffic | per-token symmetric KV quantization (kv_cache_dtype="int8"), logits rel-err 2.4% on the continuity test | LIVE 63.1 -> 50.3 GB (cache 25.8 -> 12.9 GB) | confirmed |
| C.3 | structural | — | MHA-40-heads at 32k x batch 128 is ~1.65 TB of KV (bf16): it CANNOT fit one v5e pod; with int8 + multi-pod (512 chips) the cell fits. Recorded as a finding, not forced | finding |

### Cell A: qwen3-moe-235b-a22b train_4k (memory-bound, over budget)

| # | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| A.1 | dispatch/FFN bytes scale with capacity | capacity_factor 1.25 -> 1.0 | flops 7.04 -> 6.06 s (-14%); bytes 33.3 -> 32.3 s (-3%) | partially confirmed — compute win real; memory term dominated elsewhere (kept 1.25 for routing fidelity; 1.0 is a config knob) |
| A.2 | remat recompute dominates the memory term | remat="dots" (save dot outputs) | bytes -7% but LIVE 19.9 -> 31.6 GB | REFUTED for this cell (HBM blowout) |
| A.3 | residual stacks scale 1/microbatches | microbatches 4 -> 8 for HBM-bound cells | LIVE 19.9 -> 18.1 GB (mb=16: 17.4) | confirmed, adopted |
| A.4 | the residual ~7.5 GB of temp is the donated optimizer copy | verified: alias==args (donation accepted); temp holds f32 opt-shaped buffers — CPU copy-insertion; TPU aliases in place -> ~12 GB true | finding (documented) |

### Baseline vs optimized (the three hillclimbed cells)

| cell | metric | paper-faithful baseline | optimized | Δ |
|---|---|---|---|---|
| mistral-123b prefill_32k | collective term | 28.9 s | 23.3 s | -19% (analytic TPU-native: ~11 s) |
| mistral-123b prefill_32k | mfu_bound | 0.176 | 0.219 | +24% |
| qwen1.5-32b decode_32k | memory term | 1.44 s | 0.98 s | -32% |
| qwen1.5-32b decode_32k | LIVE HBM | 87.9 GB | 50.3 GB (int8 KV) | -43% |
| qwen3-moe-235b train_4k | compute term | 7.04 s | 6.06 s (cf=1.0 knob) | -14% |
| qwen3-moe-235b train_4k | LIVE HBM | 19.9 GB | 18.1 GB (mb=8) | -9% (+7.5 GB CPU-donation artifact, A.4) |

Side effects on non-hillclimbed cells (same changes apply framework-wide):
deepseek-67b train mfu_bound 0.149 -> 0.168, mistral train 0.195 -> 0.220,
qwen1.5 train 0.138 -> 0.168, deepseek decode LIVE 15.2 -> 9.0 GB.

### Stop criterion

Three consecutive <5% iterations were reached on cells A (A.2–A.4 on the
dominant term) and B (B.2); cell C accepted changes C.1+C.2 then hit the
structural floor C.3.

### Beyond-paper (Morpheus itself)

- O(1) rolling-window features + zero-copy ring-buffer windows
  (`fast_state=True`): prediction latency drops >100x vs the modeled
  Prometheus path (fig9 bench prints both) — the paper's §5.5 bottleneck
  (state retrieval = 89.2% of delay) eliminated by construction.
- One batched predictor sweep per routing decision across all replicas
  (router), amortising state retrieval; prediction-guided hedging as
  straggler mitigation.
- int8 error-feedback gradient compression for the cross-pod (DCN) axis
  (tested on an 8-device host mesh), 4x wire reduction at <1 quantization
  step of error per step.
"""


def live_gb(m):
    return (m["temp_size_in_bytes"] + m["argument_size_in_bytes"]
            + m["output_size_in_bytes"] - m["alias_size_in_bytes"]) / 1e9


def campaign_section():
    """§Scenarios — the scenario x policy campaign table (DESIGN.md §10),
    rendered from the bench_campaign artifact."""
    art = os.path.join(os.path.dirname(__file__), "artifacts",
                       "campaign.json")
    out = ["\n## §Scenarios — co-location campaign "
           "(scenario x policy x seed grid)\n"]
    if not os.path.exists(art):
        out.append("*(missing artifact — run "
                   "`PYTHONPATH=src python benchmarks/bench_campaign.py` "
                   "to populate)*\n")
        return out
    data = json.load(open(art))
    n_seeds = len(data["seeds"])
    out.append(
        f"Every registered scenario (`repro.core.scenarios`) x every "
        f"registered policy x {n_seeds} seeds, run through the batched "
        f"campaign runner (`repro.core.campaign`): per-seed clusters "
        f"stacked on the trial axis, ONE lockstep pass per (scenario, "
        f"policy) through the policy engine's batch axis.  Wall time "
        f"{data['batched_s']:.2f}s vs {data['serial_s']:.2f}s for the "
        f"serial `run_sim` loop (**{data['speedup_x']:.1f}x**), "
        f"batched/serial drift {data['parity_drift']:.1e} "
        f"(gate: 1e-5).  Inefficiency/waste are oracle-relative "
        f"(mean ± std over seeds).\n")
    out.append("| scenario | policy | p50 s | p95 s | p99 s | "
               "ineff % | waste % | idle | shed |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for scen, cell in data["table"].items():
        for pol, r in cell.items():
            idle = "-" if "waste" not in r else f"{r['waste']:.2f}"
            shed = "-" if "shed_rate" not in r \
                else f"{r['shed_rate']:.3f}"
            out.append(
                f"| {scen} | {pol} | {r['p50_rtt']:.2f} | "
                f"{r['p95_rtt']:.2f} | {r['p99_rtt']:.2f} | "
                f"{r['inefficiency_pct']:.1f}±{r['inefficiency_std']:.1f}"
                f" | {r['resource_waste_pct']:.1f} | {idle} | {shed} |")
    # derive the narrative from the artifact so regenerated tables can
    # never contradict the prose above them
    pa = {s: c["perf_aware"]["inefficiency_pct"]
          for s, c in data["table"].items() if "perf_aware" in c}
    other = {s: [r["inefficiency_pct"] for p, r in c.items()
                 if p != "perf_aware"]
             for s, c in data["table"].items()}
    if pa and all(other.values()):
        worst = max(other, key=lambda s: max(other[s]))
        holds = all(pa[s] <= min(other[s]) for s in pa)
        out.append(
            f"\nReading the table: the performance-aware policy keeps "
            f"inefficiency at {min(pa.values()):.1f}-"
            f"{max(pa.values()):.1f}% while the reactive baselines "
            f"lose {min(min(v) for v in other.values()):.1f}-"
            f"{max(max(v) for v in other.values()):.1f}% "
            f"(worst under `{worst}`)"
            + (", and it has the lowest inefficiency in every scenario."
               if holds else
               "; see the table for the scenarios where the ordering "
               "tightens.") + "\n")
    return out


def online_section():
    """§Online — drift recovery, frozen vs retrained (DESIGN.md §11),
    rendered from the bench_online artifact."""
    art = os.path.join(os.path.dirname(__file__), "artifacts",
                       "online.json")
    out = ["\n## §Online — drift recovery "
           "(closed-loop retraining vs frozen predictors)\n"]
    if not os.path.exists(art):
        out.append("*(missing artifact — run "
                   "`PYTHONPATH=src python benchmarks/bench_online.py` "
                   "to populate)*\n")
        return out
    data = json.load(open(art))
    n_seeds = len(data["seeds"])
    out.append(
        f"Every registered drift scenario x {{frozen, online, oracle, "
        f"least_conn}} x {n_seeds} seeds through the closed-loop "
        f"simulator (`repro.core.online`): predictors train on the RTTs "
        f"the simulation observes, the regime shifts at `t_drift`, and "
        f"**recovery = (frozen - online) / (frozen - oracle)** over the "
        f"post-drift window measures how much of the inefficiency a "
        f"frozen fleet leaves on the table periodic retraining wins "
        f"back (gate: >= {data['recovery_floor']:.0%} everywhere).  "
        f"`acc` is the fleet's final rolling accuracy — the viability "
        f"signal the least-conn fallback rule consumes.\n")
    out.append("| scenario | frozen s | online s | oracle s | "
               "least_conn s | recovery | acc frozen | acc online | "
               "fallback gain s |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for name, r in data["table"].items():
        fb = r.get("fallback")
        out.append(
            f"| {name} | {r['frozen']['post_rtt']:.2f} | "
            f"{r['online']['post_rtt']:.2f} | "
            f"{r['oracle']['post_rtt']:.2f} | "
            f"{r['least_conn']['post_rtt']:.2f} | "
            f"**{r['recovery']:.2f}** | {r['accuracy_frozen']:.2f} | "
            f"{r['accuracy_online']:.2f} | "
            + ("-" if fb is None else f"{fb['gain']:.2f}") + " |")
    recs = [r["recovery"] for r in data["table"].values()]
    out.append(
        f"\nReading the table: online retraining recovers "
        f"{min(recs):.0%}-{max(recs):.0%} of the post-drift "
        f"frozen->oracle gap, and rolling accuracy recovers to ~0.8 "
        f"while a frozen fleet stays at ~0.3 — the closed-loop answer "
        f"to the paper's §7 adaptability requirement.  The fallback "
        f"column is what the viability rule alone (no retraining) "
        f"saves a frozen fleet.\n")
    return out


def capacity_section():
    """§Capacity — predictive vs reactive autoscaling Pareto table
    (DESIGN.md §12), rendered from the bench_capacity artifact."""
    art = os.path.join(os.path.dirname(__file__), "artifacts",
                       "capacity.json")
    out = ["\n## §Capacity — predictive autoscaling vs the reactive "
           "threshold baseline\n"]
    if not os.path.exists(art):
        out.append("*(missing artifact — run "
                   "`PYTHONPATH=src python benchmarks/bench_capacity.py` "
                   "to populate)*\n")
        return out
    data = json.load(open(art))
    n_seeds = len(data["seeds"])
    out.append(
        f"Every capacity scenario x {{predictive, reactive, fixed}} "
        f"autoscaler x {n_seeds} seeds through the elastic simulator "
        f"(`repro.core.capacity`): the predictive autoscaler provisions "
        f"from Little's law (trailing demand x the fleet's predicted "
        f"RTT / rho_target) and jumps straight to the required replica "
        f"count; the reactive baseline crawls ±1 per cooldown on "
        f"busy-fraction thresholds; `fixed` keeps the whole pool on.  "
        f"Each cell is the (RTT, waste, shed) triple — nan-aware p95 "
        f"over served requests, idle-provisioned replica-second "
        f"fraction, admission shed rate.  **Gate: the predictive "
        f"autoscaler Pareto-dominates reactive (lower waste at "
        f"equal-or-better p95, or better p95 at equal waste) on "
        + ", ".join(f"`{g}`" for g in data["gated"]) + ".**\n")
    out.append("| scenario | autoscaler | p95 s | mean s | waste | "
               "shed | SLO-violation s | dominates reactive |")
    out.append("|---|---|---|---|---|---|---|---|")
    for name, cell in data["table"].items():
        dom = data["dominates"].get(name)
        for v in ("predictive", "reactive", "fixed"):
            r = cell[v]
            flag = ("**yes**" if dom else "no") \
                if v == "predictive" else ""
            out.append(
                f"| {name} | {v} | {r['p95_rtt']:.2f} | "
                f"{r['mean_rtt']:.2f} | {r['waste']:.3f} | "
                f"{r['shed_rate']:.3f} | {r['slo_violation_s']:.1f} | "
                f"{flag} |")
    pred = {n: c["predictive"] for n, c in data["table"].items()}
    react = {n: c["reactive"] for n, c in data["table"].items()}
    fixed = {n: c["fixed"] for n, c in data["table"].items()}
    out.append(
        f"\nReading the table: on the gated overload scenarios the "
        f"predictive autoscaler serves a p95 of "
        f"{min(pred[g]['p95_rtt'] for g in data['gated']):.1f}-"
        f"{max(pred[g]['p95_rtt'] for g in data['gated']):.1f}s at "
        f"{min(pred[g]['waste'] for g in data['gated']):.2f}-"
        f"{max(pred[g]['waste'] for g in data['gated']):.2f} waste, "
        f"while the reactive baseline both queues worse (p95 up to "
        f"{max(react[g]['p95_rtt'] for g in data['gated']):.1f}s) AND "
        f"strands more capacity (waste up to "
        f"{max(react[g]['waste'] for g in data['gated']):.2f}) — the "
        f"paper's \"minimize resource waste\" claim, closed with the "
        f"same predictions that route requests.  The always-on pool "
        f"(`fixed`) shows the tradeoff being bought: best RTT, "
        f"{min(f['waste'] for f in fixed.values()):.2f}+ waste.\n")
    return out


def resilience_section():
    """§Resilience — the metastable-collapse study (DESIGN.md §14),
    rendered from the bench_resilience artifact."""
    art = os.path.join(os.path.dirname(__file__), "artifacts",
                       "resilience.json")
    out = ["\n## §Resilience — retry-storm collapse vs circuit breakers "
           "+ admission control\n"]
    if not os.path.exists(art):
        out.append("*(missing artifact — run "
                   "`PYTHONPATH=src python benchmarks/bench_resilience.py` "
                   "to populate)*\n")
        return out
    data = json.load(open(art))
    w = data["windows"]
    n_seeds = len(data["seeds"])
    out.append(
        f"Three client configurations ride the same 10x overload ramp "
        f"(baseline until t={w['pre_t']:.0f}s, offered load back to "
        f"baseline at t={w['recede_t']:.0f}s) x {n_seeds} seeds: "
        f"`no-retry` (25s timeout only), `naive-retries` (3 retries, "
        f"exponential backoff + jitter, no breaker), and "
        f"`breaker-admission` (the same retry budget behind per-replica "
        f"circuit breakers + admission control).  A timed-out attempt "
        f"still occupies its server for the full service time, so naive "
        f"retries amplify offered load up to 4x — **recovery goodput** "
        f"is the completed fraction of requests arriving at "
        f"t >= {w['recovery_t']:.0f}s, after the load receded to a "
        f"level the fleet served at ~1.0 goodput before the ramp.  "
        f"**Gate: healthy start (pre >= 0.95), breaker-admission "
        f"recovery >= 0.9 and >= naive + "
        f"{data['gate_margin']:.2f}.**\n")
    out.append("| variant | pre-ramp goodput | overall | recovery | "
               "timeout rate | shed rate | attempts/req | "
               "wasted work s |")
    out.append("|---|---|---|---|---|---|---|---|")
    for v in ("no-retry", "naive-retries", "breaker-admission"):
        r = data["table"][v]
        out.append(
            f"| {v} | {r['pre_goodput']:.3f} | {r['goodput']:.3f} | "
            f"{r['recovery_goodput']:.3f} | {r['timeout_rate']:.3f} | "
            f"{r['shed_rate']:.3f} | {r['attempts_per_req']:.2f} | "
            f"{r['wasted_work_s']:.0f} |")
    naive = data["table"]["naive-retries"]
    brk = data["table"]["breaker-admission"]
    ref = data["table"]["no-retry"]
    verdict = "**prevented**" if data["collapse_prevented"] \
        else "NOT prevented"
    out.append(
        f"\nReading the table: all three start at ~1.0 goodput.  After "
        f"the ramp recedes, `no-retry` drains its backlog back to "
        f"{ref['recovery_goodput']:.2f} goodput, but `naive-retries` "
        f"stays collapsed at {naive['recovery_goodput']:.2f} — the "
        f"extra damage below the pure-queueing reference is retry "
        f"amplification ({naive['attempts_per_req']:.2f} attempts/req, "
        f"{naive['wasted_work_s']:.0f}s of server time burned on "
        f"attempts nobody waited for, ~"
        f"{naive['wasted_work_s'] / max(ref['wasted_work_s'], 1.0):.0f}x "
        f"the no-retry waste).  `breaker-admission` holds the same "
        f"retry budget but fails fast while replicas are tripped and "
        f"sheds what admission cannot bound "
        f"({brk['shed_rate']:.2f} shed), recovering to "
        f"{brk['recovery_goodput']:.2f} — metastable collapse "
        f"{verdict} (`collapse_prevented` in the artifact).\n")
    return out


def dryrun_sections(art):
    """§Dry-run + §Roofline from the dry-run artifact (or a
    regeneration note when it is absent)."""
    if not os.path.exists(art):
        return ["\n## §Dry-run / §Roofline\n",
                "*(missing artifact — run `PYTHONPATH=src python -m "
                "repro.launch.dryrun --arch all --shape all --mesh "
                "both` to populate the per-cell tables)*\n"]
    data = json.load(open(art))
    out = []

    out.append("### Per-cell dry-run (single pod, 256 chips)\n")
    out.append("| cell | params | LIVE GB | fits 16GB | compile s |")
    out.append("|---|---|---|---|---|")
    for key in sorted(k for k in data if k.endswith("|single")):
        r = data[key]
        if r.get("status") != "ok":
            continue
        lv = live_gb(r["memory"])
        fits = "yes" if lv <= 16 else "**no**"
        out.append(f"| {r['arch']}\\|{r['shape']} | {r['params']/1e9:.1f}B |"
                   f" {lv:.1f} | {fits} | {r['compile_s']} |")
    n_multi = sum(1 for k, r in data.items()
                  if k.endswith("|multi") and r.get("status") == "ok")
    skips = [k for k, r in data.items() if r.get("status") == "skipped"]
    out.append(f"\nMulti-pod (2x16x16): **{n_multi}/32 cells compile** "
               f"(the pod axis shards; gradient all-reduce crosses pods).\n")
    out.append(f"Documented skips ({len(skips)}): "
               + ", ".join(s.replace('|skip', '') for s in sorted(skips))
               + " — full-attention archs at 500k context (DESIGN.md §4).\n")

    out.append("\n## §Roofline — three terms per cell (single pod)\n")
    out.append("Constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link "
               "ICI per chip.  Terms in seconds/step (per device).  "
               "useful = MODEL_FLOPS / (HLO_FLOPs x chips); mfu_bound = "
               "useful model FLOP/s at the dominant bound vs peak.\n")
    out.append("| cell | compute s | memory s | collective s | dominant | "
               "useful | mfu_bound | what moves it down |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in roofline.full_table(art):
        out.append(
            f"| {r['arch']}\\|{r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['mfu_bound']:.3f} | {r['advice']} |")
    out.append("""
Reading the table: training cells are memory/collective-bound at this mesh
(remat recompute + FSDP gathers + per-microbatch grad reduce-scatter);
prefill cells are collective-bound (sequence-parallel gathers, x2-inflated
by CPU f32 dots — see accounting note 2); decode cells are memory-bound
(KV-cache residency — the roofline-correct regime for single-token decode).
The best train cells reach mfu_bound ~0.15–0.20 at the CPU-accounted bound;
with the f32-inflation halved (TPU-native collectives) the analytic bound
is ~0.3–0.4 MFU for the large dense models — the §Perf log records the
iterations that got there and where each remaining second sits.
""")
    return out


def main():
    out = [HEADER]
    out.extend(campaign_section())
    out.extend(online_section())
    out.extend(capacity_section())
    out.extend(resilience_section())
    out.extend(dryrun_sections(roofline.ARTIFACT))
    out.append(PERF_LOG)
    path = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    with open(path, "w") as f:
        f.write("\n".join(out))
    print(f"wrote {os.path.abspath(path)} ({len(out)} lines)")


if __name__ == "__main__":
    main()
